"""Per-brick, per-phase telemetry ledger with JSON persistence.

The accumulating-record pattern (SNIPPETS.md ``FlopCount`` +
``save_roofline_data``): one :class:`PhaseRecord` per (brick, phase)
holding flops / HBM bytes / link bytes / tokens / joules / seconds, with
closed arithmetic (``+`` merges, ``*`` scales) so ledgers from separate
bench runs compose into one trajectory file.

Two population paths, deliberately sharing one schema:

* **static** (:meth:`Ledger.modeled`) — compile-time roofline+energy
  numbers from ``core/scheduler.brick_cost`` (``analysis/roofline`` +
  ``analysis/energy`` constants).  ``samples == 0`` marks these rows as
  modeled, never measured.
* **dynamic** (:meth:`repro.telemetry.probes.WallProbe.to_ledger`) —
  wall-time samples recorded by the plan/engine probes; ``samples > 0``
  marks a row as measured, which is what
  :meth:`repro.telemetry.calibration.CostCalibration.from_ledger` feeds
  back into the scheduler.

Phase token semantics: bricks form a chain, so every brick of a phase
sees the SAME token stream — a phase's token count is the **max** over
its bricks (never the sum), while seconds/joules add across bricks.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterator, Mapping, Optional, Tuple

PHASES = ("stage", "prefill", "decode")

# which brick kinds run in which phase (the chain splits at the TABM
# edge: vision-side bricks stage, decoder-side bricks prefill + decode)
PHASE_KINDS = {
    "stage": ("frontend", "encoder", "projector"),
    "prefill": ("frontend", "encoder", "projector", "embed", "decoder",
                "head"),
    "decode": ("embed", "decoder", "head"),
}


@dataclass
class PhaseRecord:
    """One (brick, phase) accumulator — the FlopCount of this repo.

    ``samples`` counts *measured* wall-time observations folded in;
    modeled (static) rows keep ``samples == 0`` so downstream consumers
    can tell observation from prediction in a merged ledger."""

    flops: float = 0.0
    bytes: float = 0.0          # HBM/weight traffic
    link_bytes: float = 0.0     # interconnect traffic
    tokens: float = 0.0
    joules: float = 0.0
    seconds: float = 0.0
    samples: int = 0

    def __add__(self, other: "PhaseRecord") -> "PhaseRecord":
        return PhaseRecord(
            self.flops + other.flops, self.bytes + other.bytes,
            self.link_bytes + other.link_bytes, self.tokens + other.tokens,
            self.joules + other.joules, self.seconds + other.seconds,
            self.samples + other.samples)

    def __mul__(self, k: float) -> "PhaseRecord":
        """Scale the extensive fields; ``samples`` stays a count."""
        return PhaseRecord(
            self.flops * k, self.bytes * k, self.link_bytes * k,
            self.tokens * k, self.joules * k, self.seconds * k,
            self.samples)

    __rmul__ = __mul__

    @property
    def j_per_token(self) -> float:
        return self.joules / self.tokens if self.tokens else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.seconds if self.seconds else 0.0

    def to_dict(self) -> Dict[str, float]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "PhaseRecord":
        return cls(**{k: d.get(k, 0) for k in
                      ("flops", "bytes", "link_bytes", "tokens", "joules",
                       "seconds")}, samples=int(d.get("samples", 0)))


@dataclass
class Ledger:
    """Accumulating (brick, phase) -> :class:`PhaseRecord` table."""

    records: Dict[Tuple[str, str], PhaseRecord] = field(default_factory=dict)
    meta: Dict[str, str] = field(default_factory=dict)

    # -- accumulation -------------------------------------------------------
    def accumulate(self, brick: str, phase: str, rec: Optional[PhaseRecord]
                   = None, **fields) -> PhaseRecord:
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r} (want one of "
                             f"{PHASES})")
        add = rec if rec is not None else PhaseRecord(**fields)
        key = (brick, phase)
        self.records[key] = self.records.get(key, PhaseRecord()) + add
        return self.records[key]

    def record(self, brick: str, phase: str) -> PhaseRecord:
        return self.records.get((brick, phase), PhaseRecord())

    def items(self) -> Iterator[Tuple[str, str, PhaseRecord]]:
        for (brick, phase), rec in sorted(self.records.items()):
            yield brick, phase, rec

    def __len__(self) -> int:
        return len(self.records)

    # -- algebra ------------------------------------------------------------
    def merge(self, other: "Ledger") -> "Ledger":
        """In-place fold of another ledger (record-wise ``+``)."""
        for (brick, phase), rec in other.records.items():
            self.accumulate(brick, phase, rec)
        for k, v in other.meta.items():
            self.meta.setdefault(k, v)
        return self

    def __add__(self, other: "Ledger") -> "Ledger":
        return Ledger(dict(self.records), dict(self.meta)).merge(other)

    def scale(self, k: float) -> "Ledger":
        return Ledger({key: rec * k for key, rec in self.records.items()},
                      dict(self.meta))

    # -- derived ------------------------------------------------------------
    def total(self, phase: Optional[str] = None) -> PhaseRecord:
        """Sum of records (one phase, or all); ``tokens`` uses the
        chain max-rule per phase (see module docstring)."""
        phases = PHASES if phase is None else (phase,)
        out = PhaseRecord()
        for ph in phases:
            recs = [r for (b, p), r in self.records.items() if p == ph]
            if not recs:
                continue
            for r in recs:
                out = out + (r * 1.0)
            out.tokens -= sum(r.tokens for r in recs)
            out.tokens += max(r.tokens for r in recs)
        return out

    def j_per_token(self, phase: Optional[str] = None) -> float:
        return self.total(phase).j_per_token

    def tokens_per_s(self, phase: Optional[str] = None) -> float:
        return self.total(phase).tokens_per_s

    # -- persistence (à la SNIPPETS.md save_roofline_data) ------------------
    def to_dict(self) -> Dict:
        return {"schema": 1, "meta": dict(self.meta),
                "records": {f"{b}/{p}": r.to_dict()
                            for (b, p), r in sorted(self.records.items())}}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Ledger":
        led = cls(meta=dict(d.get("meta", {})))
        for key, rec in d.get("records", {}).items():
            brick, _, phase = key.rpartition("/")
            led.accumulate(brick, phase, PhaseRecord.from_dict(rec))
        return led

    def save(self, path: str) -> str:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)       # atomic: readers never see a torn file
        return path

    @classmethod
    def load(cls, path: str) -> "Ledger":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- static population (compile-time roofline + energy model) -----------
    @classmethod
    def modeled(cls, graph, accel_for, phase_tokens: Mapping[str, int],
                batch: int = 1) -> "Ledger":
        """Ledger predicted by the cost model, no execution needed.

        ``accel_for``: one :class:`~repro.core.scheduler.Accelerator`
        for every brick, or a ``{brick_name: Accelerator}`` map (e.g.
        built from a ``Placement``).  ``phase_tokens``: tokens per call
        per phase, e.g. ``{"stage": 729, "prefill": 64, "decode": 1}``;
        bricks participate per :data:`PHASE_KINDS`.  Rows carry
        ``samples == 0``: modeled, not measured."""
        # local import: scheduler imports telemetry.calibration, so the
        # static-population edge must not close an import cycle
        from repro.core.scheduler import brick_cost
        led = cls(meta={"source": "modeled"})
        for phase, n_tokens in phase_tokens.items():
            for b in graph.bricks:
                if b.kind not in PHASE_KINDS.get(phase, ()):
                    continue
                acc = (accel_for[b.name] if isinstance(accel_for, Mapping)
                       else accel_for)
                c = brick_cost(b, acc, n_tokens, batch=batch)
                if not c.feasible:
                    continue
                units = n_tokens * max(1, batch)
                led.accumulate(
                    b.name, phase,
                    flops=b.flops_per_token * units,
                    bytes=float(max(b.param_bytes, 1)),
                    tokens=float(units), joules=c.energy_j,
                    seconds=c.latency_s, samples=0)
        return led
