"""Wall-time probes: the ledger's dynamic (measured) population path.

A :class:`WallProbe` collects timestamped per-brick samples from the
hot paths (``ExecutionPlan.run`` / ``produce_many``, the engine's
prefill and cohort-decode spans).  The collector is deliberately
host-only — ``time.perf_counter`` spans stamped with ``time.monotonic``
and a lock-free ``deque`` append — so recording is legal inside the
replint host-sync hot paths (``WallProbe.record`` is itself on that
list: no device syncs may ever creep in here).

Measurement caveat, stated once: on asynchronous backends a span that
does not end at an existing host sync measures *dispatch*, not device
completion.  The engine's spans end at syncs it already pays (the
per-token sampling read after decode, the ``insert_many`` length reads
after prefill), so those are true wall times; the plan's per-brick
staging spans are dispatch-inclusive lower bounds, still ordered
correctly for *relative* calibration.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, NamedTuple, Optional

from repro.telemetry.ledger import Ledger


class Sample(NamedTuple):
    """One measured span: ``t`` is ``time.monotonic()`` at record time
    (orders samples across threads), ``dt`` the measured seconds,
    ``tokens`` how many tokens the span processed."""

    brick: str
    phase: str          # stage | prefill | decode
    t: float
    dt: float
    tokens: int


class WallProbe:
    """Thread-safe accumulator of :class:`Sample` spans.

    Appends are a single ``deque.append`` (atomic under the GIL), so the
    engine's staging worker threads and the step loop share one probe
    without a lock on the record path; the bound keeps a long-running
    server from growing it without limit (same contract as the engine
    trace)."""

    def __init__(self, maxlen: int = 65536):
        self._samples: Deque[Sample] = deque(maxlen=maxlen)

    def record(self, brick: str, phase: str, dt: float, tokens: int = 0
               ) -> None:
        self._samples.append(Sample(brick, phase, time.monotonic(), dt,
                                    tokens))

    def span(self, brick: str, phase: str, tokens: int = 0):
        """Context-manager form for cold paths; hot paths inline the
        two-line ``perf_counter`` form instead (no generator frames on
        the decode loop)."""
        return _Span(self, brick, phase, tokens)

    def samples(self) -> List[Sample]:
        return list(self._samples)

    def __len__(self) -> int:
        return len(self._samples)

    def clear(self) -> None:
        self._samples.clear()

    def to_ledger(self, meta: Optional[dict] = None) -> Ledger:
        """Fold the samples into a measured :class:`Ledger` (one record
        per brick/phase, ``samples`` = observation count).  Joules stay
        zero — the container has no hardware PMU, so measured energy
        only enters via the fleet simulator / modeled merge; calibration
        built from this ledger corrects *latency* and falls back to the
        modeled energy term."""
        led = Ledger(meta={"source": "probe", **(meta or {})})
        for s in self.samples():
            led.accumulate(s.brick, s.phase, seconds=s.dt,
                           tokens=float(s.tokens), samples=1)
        return led


class _Span:
    def __init__(self, probe: WallProbe, brick: str, phase: str,
                 tokens: int):
        self.probe, self.brick, self.phase, self.tokens = (
            probe, brick, phase, tokens)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.probe.record(self.brick, self.phase,
                          time.perf_counter() - self._t0, self.tokens)
        return False
