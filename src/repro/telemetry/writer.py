"""The ONE benchmark emitter: CSV side-emit + versioned BENCH_<pr>.json.

Every benchmark exit path routes through here (``benchmarks/common.py``
delegates): the classic ``name,us_per_call,derived`` CSV keeps printing
and side-emitting for artifact diffing, while :func:`merge_section`
accumulates each bench's rows, gateable metrics, and measured ledger
into one versioned ``BENCH_<pr>.json`` at the repo root —
read-modify-write with an atomic replace, so the kernel / staging /
decode / fleet smokes, run as separate processes, build ONE file.

Metric schema (what ``scripts/bench_gate.py`` consumes)::

    {"value": 123.4, "better": "higher"|"lower",
     "gate": true|false, "rel_tol": 0.10}

``gate: false`` records a trajectory without failing CI on it — raw
wall-clock throughputs are machine-dependent (a laptop baseline vs a CI
runner differs far beyond any honest tolerance), so they ride along
ungated while machine-independent metrics (the fleet simulator's
tokens/s and J/token — simulated time over a modeled energy integral —
and deterministic traffic ratios) carry the 10 % regression gate the
trajectory needs.
"""
from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.telemetry.ledger import Ledger

# the versioned ledger this PR's benches write; bump per PR so the repo
# root accumulates a BENCH_8.json, BENCH_9.json, ... trajectory
CURRENT_PR = 10
SCHEMA = 1
CSV_HEADER = "name,us_per_call,derived"

RowLike = Union[Tuple[str, float, str], Sequence]


def _row_tuple(row: RowLike) -> Tuple[str, float, str]:
    if hasattr(row, "name") and hasattr(row, "us_per_call"):
        return (row.name, float(row.us_per_call), str(row.derived))
    name, us, derived = row
    return (str(name), float(us), str(derived))


def csv_lines(rows: Iterable[RowLike]) -> List[str]:
    lines = [CSV_HEADER]
    for row in rows:
        name, us, derived = _row_tuple(row)
        lines.append(f"{name},{us:.1f},{derived}")
    return lines


def write_csv(path: str, rows: Iterable[RowLike]) -> str:
    with open(path, "w") as f:
        f.write("\n".join(csv_lines(rows)) + "\n")
    return path


def metric(value: float, better: str = "higher", gate: bool = True,
           rel_tol: float = 0.10) -> Dict:
    """One gateable metric entry (see module docstring for semantics)."""
    assert better in ("higher", "lower"), better
    return {"value": float(value), "better": better, "gate": bool(gate),
            "rel_tol": float(rel_tol)}


def bench_path(root: str = ".", pr: int = CURRENT_PR) -> str:
    return str(Path(root) / f"BENCH_{pr}.json")


def read_bench(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def merge_section(path: str, section: str, *,
                  rows: Optional[Iterable[RowLike]] = None,
                  metrics: Optional[Dict[str, Dict]] = None,
                  ledger: Optional[Ledger] = None,
                  pr: int = CURRENT_PR) -> Dict:
    """Fold one bench's output into the versioned ledger file.

    Read-modify-write: an existing file for the SAME pr keeps its other
    sections (separate bench processes accumulate); a stale or foreign
    file is restarted.  The ledger merges record-wise, so static and
    measured rows from different benches compose."""
    data: Dict = {}
    try:
        data = read_bench(path)
    except (OSError, json.JSONDecodeError):
        pass
    if data.get("schema") != SCHEMA or data.get("pr") != pr:
        data = {"schema": SCHEMA, "pr": pr, "sections": {}, "ledger": None}
    sec: Dict = {}
    if rows is not None:
        sec["rows"] = [list(_row_tuple(r)) for r in rows]
    if metrics is not None:
        sec["metrics"] = dict(metrics)
    data.setdefault("sections", {})[section] = sec
    if ledger is not None:
        base = (Ledger.from_dict(data["ledger"]) if data.get("ledger")
                else Ledger())
        data["ledger"] = base.merge(ledger).to_dict()
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return data


def gated_metrics(data: Dict) -> Dict[str, Dict]:
    """Flatten ``{section}/{metric}`` -> entry for every gated metric."""
    out = {}
    for sec, body in (data.get("sections") or {}).items():
        for name, m in (body.get("metrics") or {}).items():
            if m.get("gate"):
                out[f"{sec}/{name}"] = m
    return out


def latest_baseline(root: str = ".", exclude: Optional[str] = None
                    ) -> Optional[str]:
    """Highest-numbered committed ``BENCH_<n>.json`` under ``root``,
    skipping the candidate file itself (compared by resolved path)."""
    best: Tuple[int, Optional[str]] = (-1, None)
    skip = Path(exclude).resolve() if exclude else None
    for p in Path(root).glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", p.name)
        if not m or (skip is not None and p.resolve() == skip):
            continue
        n = int(m.group(1))
        if n > best[0]:
            best = (n, str(p))
    return best[1]
