"""Fleet-scale battery simulation: the paper's one device, times N.

A RAPS-``FLOPSManager``-style simulator (SNIPPETS.md): aggregate fleet
state lives in numpy vectors (levels, backlogs, survival), while each
device keeps its own :class:`~repro.core.power.PMU` and shares one
:class:`~repro.core.power.PowerPolicy` — every tick each device reads
its battery level, takes the policy's state/knobs, admits its share of
an arrival trace, processes tokens phase-by-phase at the modality
profile's rates, and drains the modeled joules into its PMU.  Devices
traverse UNCONSTRAINED -> THROTTLED -> CRITICAL as charge falls and die
at empty, yielding fleet-wide tokens/s, J/token, and a survival-hours
histogram — the paper's single-device Fig. 8 story scaled to a fleet.

The per-phase energy profile comes from a telemetry
:class:`~repro.telemetry.ledger.Ledger` ("Modality Inflation",
PAPERS.md: vision staging, prefill and decode differ enough per token
that one blended J/token misprices the power policy's cuts), so the
same file a bench run wrote drives the fleet.

Determinism: the only randomness is the per-device offered-load draw at
construction (seeded); stepping is pure arithmetic with a fractional
arrival accumulator — same seed, same fleet, same report, which is what
lets ``BENCH_<pr>.json`` gate fleet tokens/s and J/token at a tight
tolerance across machines.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Mapping, NamedTuple, Optional, Set, Tuple

import numpy as np

from repro.core.power import PMU, PowerPolicy, PowerState
from repro.telemetry.ledger import PHASES, Ledger

# tokens one request pushes through each phase: a frame's worth of
# vision staging, a short prompt, a short answer (fig8's event shape)
DEFAULT_REQUEST_TOKENS = {"stage": 64, "prefill": 32, "decode": 48}


@dataclass(frozen=True)
class ModalityProfile:
    """Per-phase J/token and tokens/s of ONE device's pipeline."""

    j_per_token: Mapping[str, float]
    tokens_per_s: Mapping[str, float]
    idle_w: float = 0.35            # fig8's standby draw (A55 + LPDDR)

    @classmethod
    def from_ledger(cls, ledger: Ledger, idle_w: float = 0.35
                    ) -> "ModalityProfile":
        """Sample the per-modality characterization from a ledger (the
        measured-or-modeled file a bench run wrote)."""
        jpt, tps = {}, {}
        for phase in PHASES:
            tot = ledger.total(phase)
            if tot.tokens <= 0:
                raise ValueError(f"ledger has no {phase!r} rows to "
                                 f"characterize the fleet from")
            jpt[phase] = tot.j_per_token
            tps[phase] = tot.tokens_per_s
        return cls(jpt, tps, idle_w=idle_w)

    @classmethod
    def default_edge(cls) -> "ModalityProfile":
        """RK3566-class fallback (no ledger at hand): numbers of the
        modeled edge pipeline at fig8's event shape — stage is
        vision-heavy but parallel, decode is memory-bound and slow."""
        return cls(
            j_per_token={"stage": 0.004, "prefill": 0.003, "decode": 0.012},
            tokens_per_s={"stage": 450.0, "prefill": 700.0, "decode": 40.0})


class FleetTraceEvent(NamedTuple):
    """One device-tick, replayable: drain ``joules`` over ``dt`` into a
    fresh PMU and the recorded ``state``/``level`` must reproduce."""

    t: float
    device: int
    state: str
    level: float                # state of charge AFTER this tick's drain
    tokens: float
    joules: float
    dt: float


@dataclass(frozen=True)
class FleetReport:
    n_devices: int
    hours: float                    # simulated horizon actually stepped
    tokens_per_s: float             # fleet aggregate over simulated time
    j_per_token: float
    survival_hours: np.ndarray      # per device; alive at horizon = horizon
    dead: int
    states_seen: Set[str]
    state_ticks: Dict[str, int]
    shed_tokens: float              # offered but not admitted (throttling)

    @property
    def survival_hours_p50(self) -> float:
        return float(np.median(self.survival_hours))

    def histogram(self, bins: int = 8) -> Tuple[np.ndarray, np.ndarray]:
        return np.histogram(self.survival_hours, bins=bins)

    def summary(self) -> str:
        counts, edges = self.histogram()
        bars = "\n".join(
            f"  {lo:5.1f}-{hi:5.1f} h | {'#' * int(c)} {int(c)}"
            for lo, hi, c in zip(edges[:-1], edges[1:], counts))
        return (
            f"fleet: {self.n_devices} devices, {self.hours:.1f} h horizon\n"
            f"  tokens/s (fleet): {self.tokens_per_s:.1f}\n"
            f"  J/token  (fleet): {self.j_per_token:.4f}\n"
            f"  survival p50:     {self.survival_hours_p50:.2f} h "
            f"({self.dead}/{self.n_devices} dead)\n"
            f"  states seen:      {sorted(self.states_seen)}\n"
            f"  ticks per state:  {self.state_ticks}\n"
            f"  shed tokens:      {self.shed_tokens:.0f}\n"
            f"survival-hours histogram:\n{bars}")


class FleetSimulator:
    """Hundreds-to-thousands of simulated battery devices, one policy.

    ``request_hz``: per-device offered load is drawn uniformly from this
    range at construction (the only RNG use).  ``request_tokens``: the
    per-phase token cost of one request.  ``record_trace`` keeps a
    bounded :class:`FleetTraceEvent` log for replay tests."""

    def __init__(self, n_devices: int, profile: ModalityProfile, *,
                 policy: Optional[PowerPolicy] = None, seed: int = 0,
                 battery_mah: float = 2000.0, volts: float = 3.7,
                 dt_s: float = 30.0,
                 request_hz: Tuple[float, float] = (0.02, 0.25),
                 request_tokens: Optional[Mapping[str, int]] = None,
                 record_trace: bool = False, trace_cap: int = 65536):
        if n_devices < 1:
            raise ValueError("need at least one device")
        rng = np.random.default_rng(seed)
        self.profile = profile
        self.policy = policy or PowerPolicy()
        self.dt_s = float(dt_s)
        self.request_tokens = dict(request_tokens or DEFAULT_REQUEST_TOKENS)
        self._req_vec = np.array([self.request_tokens[p] for p in PHASES],
                                 float)
        self._jpt = np.array([profile.j_per_token[p] for p in PHASES])
        self._tps = np.array([profile.tokens_per_s[p] for p in PHASES])
        self.pmus = [PMU(battery_mah=battery_mah, volts=volts)
                     for _ in range(n_devices)]
        # FLOPSManager-style aggregate state: one vector per fleet signal
        self.levels = np.ones(n_devices)
        self.alive = np.ones(n_devices, dtype=bool)
        self.rates_hz = rng.uniform(*request_hz, size=n_devices)
        self._carry = np.zeros(n_devices)       # fractional arrivals
        self.backlog = np.zeros((n_devices, len(PHASES)))
        self.survival_h = np.zeros(n_devices)
        self.t = 0.0
        self.tokens_done = 0.0
        self.joules_spent = 0.0
        self.shed_tokens = 0.0
        self.states_seen: Set[str] = set()
        self.state_ticks: Dict[str, int] = {s.value: 0 for s in PowerState}
        self.trace: Optional[Deque[FleetTraceEvent]] = (
            deque(maxlen=trace_cap) if record_trace else None)

    def step(self) -> None:
        """Advance every live device by ``dt_s`` of simulated time.

        Host-side arithmetic only (this method is on replint's host-sync
        hot-path list: a device sync per device-tick would serialize a
        thousand-device fleet)."""
        dt = self.dt_s
        self.t += dt
        req = self._req_vec
        for i, pmu in enumerate(self.pmus):
            if not self.alive[i]:
                continue
            st = self.policy.state(pmu.level)
            knobs = self.policy.knobs(pmu.level)
            self.states_seen.add(st.value)
            self.state_ticks[st.value] += 1
            # offered arrivals: deterministic fractional accumulator
            self._carry[i] += self.rates_hz[i] * dt
            offered = math.floor(self._carry[i])
            self._carry[i] -= offered
            if knobs.cascade:
                # critical: on-demand cascade serves ONE event per tick,
                # everything else is shed (paper state iii)
                admitted = min(offered, 1)
            elif st is PowerState.UNCONSTRAINED:
                admitted = offered
            else:
                # proportional throttling sheds offered load by alpha
                admitted = math.floor(offered * knobs.admission_rate)
            self.shed_tokens += (offered - admitted) * req.sum()
            self.backlog[i] += admitted * req
            # per-phase service capacity this tick, throttled through the
            # same knob the engine throttles its memory path with
            speed = 0.25 if knobs.cascade else knobs.mem_clock_scale
            done = np.minimum(self.backlog[i], self._tps * dt * speed)
            self.backlog[i] -= done
            # cascade drops to a deep-sleep duty cycle between events;
            # the other states pay full standby (fig8's 0.35 W floor)
            idle = self.profile.idle_w * (0.5 if knobs.cascade else 1.0)
            joules = (done * self._jpt).sum() + idle * dt
            pmu.drain(joules, dt)
            self.levels[i] = pmu.level
            tokens = done.sum()
            self.tokens_done += tokens
            self.joules_spent += joules
            if self.trace is not None:
                self.trace.append(FleetTraceEvent(
                    self.t, i, st.value, pmu.level, tokens, joules, dt))
            if pmu.level <= 0.0:
                self.alive[i] = False
                self.survival_h[i] = self.t / 3600.0

    def run(self, hours: float) -> FleetReport:
        steps = max(1, round(hours * 3600.0 / self.dt_s))
        for _ in range(steps):
            if not self.alive.any():
                break
            self.step()
        return self.report()

    def report(self) -> FleetReport:
        horizon_h = self.t / 3600.0
        # devices alive at the horizon are right-censored at the horizon
        survival = np.where(self.alive, horizon_h, self.survival_h)
        return FleetReport(
            n_devices=len(self.pmus), hours=horizon_h,
            tokens_per_s=self.tokens_done / max(self.t, 1e-9),
            j_per_token=self.joules_spent / max(self.tokens_done, 1e-9),
            survival_hours=survival,
            dead=int((~self.alive).sum()),
            states_seen=set(self.states_seen),
            state_ticks=dict(self.state_ticks),
            shed_tokens=self.shed_tokens)


def replay_trace(events, *, battery_mah: float = 2000.0,
                 volts: float = 3.7,
                 policy: Optional[PowerPolicy] = None
                 ) -> Dict[int, list]:
    """Re-drive recorded :class:`FleetTraceEvent` s through fresh
    PMU/PowerPolicy instances: for each device, drain the recorded
    joules tick-by-tick and return ``[(state, level), ...]`` as the
    fresh state machine saw them.  The satellite test asserts these
    match the recording — the power state machine is a pure function of
    the drain history."""
    pol = policy or PowerPolicy()
    pmus: Dict[int, PMU] = {}
    out: Dict[int, list] = {}
    for ev in events:
        pmu = pmus.setdefault(ev.device,
                              PMU(battery_mah=battery_mah, volts=volts))
        # state is read BEFORE the tick's drain, as the simulator does
        st = pol.state(pmu.level)
        pmu.drain(ev.joules, ev.dt)
        out.setdefault(ev.device, []).append((st.value, pmu.level))
    return out
