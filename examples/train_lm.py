"""Train a small LM for a few hundred steps with the full substrate:
packed synthetic data, AdamW + cosine schedule, async checkpointing, and
crash-resume (kill it mid-run and rerun — it restores and continues).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.configs import get_config
from repro.data import multimodal_batch_iter
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, fit

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="stablelm-1.6b")
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
data = multimodal_batch_iter(cfg, global_batch=8, seq_len=128)
res = fit(cfg,
          OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
          TrainConfig(steps=args.steps, ckpt_dir="ckpts/example",
                      ckpt_every=50, log_every=20),
          data)

losses = [m["loss"] for m in res.metrics_history]
print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({len(losses)} steps, ckpts in ckpts/example)")
assert losses[-1] < losses[0]
print("OK")
