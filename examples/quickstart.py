"""Quickstart: decompose a multimodal model into bricks, schedule them
across accelerators, and serve a request — the NANOMIND pipeline in ~40
lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.scheduler import (edge_accelerators, populate_brick_bytes,
                                  schedule)
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine

# 1. the paper's own model (LLaVA-OneVision-0.5B class), CPU-reduced
cfg = get_config("llava-onevision-0.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)

# 2. decompose into bricks and pick a placement (the paper's core move)
graph = decompose(cfg)
populate_brick_bytes(graph, params)
accels = edge_accelerators()
placement = schedule(graph, accels, n_tokens=64, objective="latency")
print("bricks:    ", graph.names())
print("placement: ", placement)

# 2b. the placement is executable: compile it to an ExecutionPlan (bound,
#     jit-cached per-brick callables) and run one forward through it
plan = compile_plan(graph, params, placement=placement, accels=accels)
print("plan:      ", plan.describe())
rng = np.random.default_rng(0)
logits, _ = plan.run({
    "tokens": rng.integers(3, 400, (1, 16)).astype(np.int32),
    "vision_feats": rng.standard_normal(
        (1, cfg.vision_tokens, cfg.vision_feat_dim)).astype(np.float32)
    * 0.02})
print("plan run:  ", tuple(logits.shape), "logits")

# 3. serve one multimodal request through the continuous-batching engine
#    (encoder -> TABM ring slot -> decoder, zero-copy hand-off)
engine = ServingEngine(cfg, params, n_slots=2, max_len=256)
rng = np.random.default_rng(0)
engine.submit(Request(
    rid=0,
    tokens=rng.integers(3, 400, 16).astype(np.int32),
    vision_feats=rng.standard_normal(
        (1, cfg.vision_tokens, cfg.vision_feat_dim)).astype(np.float32)
    * 0.02,
    max_new_tokens=12))
done = engine.run()

print("generated: ", done[0].out_tokens)
print(f"throughput: {engine.stats.tokens_per_s():.1f} tok/s   "
      f"e2e: {done[0].e2e_latency:.2f}s")
print("tabm:      ", engine.tabm.stats)
