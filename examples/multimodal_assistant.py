"""End-to-end driver: the battery-powered multimodal assistant.

Simulates the paper's demo device across a full battery discharge:
camera/voice events arrive, the PMU drains with each inference (modeled
energy), and the three-state policy visibly changes behavior —
UNCONSTRAINED parallel serving -> THROTTLED (alpha-scaled admission;
deep throttling re-lowers the encoder bricks to the host backend via
plan.relower) -> CRITICAL (on-demand cascade: the whole graph on the
transient HostBackend, one-shot load->execute->release).

    PYTHONPATH=src python examples/multimodal_assistant.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.energy import EDGE_GPU, EDGE_NPU, step_energy
from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.power import BatteryAwareExecutor, PMU, PowerState
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine

cfg = get_config("llava-onevision-0.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
graph = decompose(cfg)
# CRITICAL-mode lowering: same graph, host substrate (what CascadeRunner
# wraps); shares jit-cached brick executables with the engine's plan
cascade = compile_plan(graph, params, backend="host")

# a small battery so the demo crosses all three states quickly; the
# engine's serving plan lowers through the committed-device backend
executor = BatteryAwareExecutor(PMU(battery_mah=1.4))
engine = ServingEngine(cfg, params, n_slots=4, max_len=256,
                       executor=executor, backend="device")
rng = np.random.default_rng(0)


def camera_event(rid):
    return Request(
        rid=rid, tokens=rng.integers(3, 400, 12).astype(np.int32),
        vision_feats=rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)
        ).astype(np.float32) * 0.02,
        max_new_tokens=6)


# modeled energy per inference event on the edge profiles (vision on NPU,
# decode on GPU — the scheduler's placement)
E_EVENT = (step_energy(EDGE_NPU, 2 * 400e6 * 729, 8e8, 0)
           + step_energy(EDGE_GPU, 2 * 0.5e9 * 48, 3e8, 0))

rid = 0
seen_states = []
for event in range(40):
    state, knobs, objective = executor.current()
    if not seen_states or seen_states[-1] != state:
        seen_states.append(state)
        print(f"\n=== battery {executor.pmu.level:5.0%}  ->  {state.value} "
              f"(objective={objective}, max_batch={knobs.max_batch}, "
              f"fps={knobs.frame_rate_hz:.0f}, "
              f"demote={knobs.backend_demotion or '-'}) ===")

    if knobs.cascade:
        # CRITICAL: event-triggered one-shot cascade, minimal residency
        out, trace = cascade.run({
            "tokens": jnp.asarray(camera_event(rid).tokens)[None],
            "vision_feats": jnp.asarray(camera_event(rid).vision_feats)})
        print(f"  [cascade] event {event}: logits {tuple(out.shape)}, "
              f"peak/sum resident = "
              f"{trace.peak_bytes / trace.sum_bytes:.0%}")
    else:
        engine.submit(camera_event(rid))
        rid += 1
        for _ in range(8):
            engine.step()
            if not engine.live and not engine.queue:
                break
        if engine.done:
            last = engine.done[-1]
            enc_be = engine.plan.backend_of("projector").name
            print(f"  [engine ] req {last.rid}: {len(last.out_tokens)} "
                  f"tokens, e2e {last.e2e_latency:.2f}s, "
                  f"encoder backend={enc_be}")
    executor.pmu.drain(E_EVENT, dt=1.0)

print(f"\nstates visited: {[s.value for s in seen_states]}")
print(f"engine served {len(engine.done)} requests; "
      f"tabm stats {engine.tabm.stats}")
assert seen_states == [PowerState.UNCONSTRAINED, PowerState.THROTTLED,
                       PowerState.CRITICAL]
print("OK: policy traversed unconstrained -> throttled -> critical")
