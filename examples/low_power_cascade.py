"""On-Demand Cascade Inference (paper Fig. 2), standalone.

The cascade is a *backend lowering*: ``compile_plan(..., backend="host")``
lowers every brick through the transient HostBackend — params host-side,
each brick load -> execute -> release on the pinned host thread (what the
paper's Critical Conservation mode does on the NPU/DSP).  The trace shows
the lifecycle live, and the output equals the monolithic forward while
peak memory stays near max(brick) instead of sum(bricks).

    PYTHONPATH=src python examples/low_power_cascade.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.bricks import brick_param_bytes, decompose
from repro.core.plan import compile_plan
from repro.launch.steps import init_params
from repro.models.model import lm_forward

cfg = get_config("stablelm-12b").reduced(n_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
graph = decompose(cfg)
# the battery policy's CRITICAL lowering, selected explicitly: same graph,
# same jit-cached brick executables, host substrate (CascadeRunner is the
# thin convenience wrapper over exactly this call)
plan = compile_plan(graph, params, backend="host")
print("lowering:", plan.describe())

tokens = jnp.arange(24)[None] % 60 + 3
out, trace = plan.run({"tokens": tokens})

print("event trace (resident bytes after each phase):")
for e in trace.events:
    bar = "#" * int(40 * e.resident_bytes / max(1, trace.peak_bytes))
    print(f"  {e.brick:10s} {e.phase:8s} {e.resident_bytes/1e6:8.2f}MB {bar}")

sizes = brick_param_bytes(graph, params)
print("\nbrick sizes:", {k: f"{v/1e6:.2f}MB" for k, v in sizes.items()})
print(f"peak resident: {trace.peak_bytes/1e6:.2f}MB")
print(f"monolithic sum: {trace.sum_bytes/1e6:.2f}MB")
print(f"peak/sum: {trace.peak_bytes/trace.sum_bytes:.0%}  "
      f"(the paper's max-not-sum claim)")

mono, _ = lm_forward(params, cfg, tokens)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                            - mono.astype(jnp.float32))))
print(f"cascade vs monolithic max |dlogit| = {err:.2e}")
assert err < 0.1 and trace.peak_bytes < trace.sum_bytes
print("OK")
