"""Shared benchmark helpers: timing, CSV rows, analytic memory accounting."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax

_LABEL_BITS = {"fp16": 16, "bf16": 16, "q8f16": 8.5, "q4f16": 4.5,
               "q2f16": 2.5}   # +.5: per-group fp32 scales at g=64


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def emit_rows(rows: List[Row], *, out: Optional[str] = None,
              bench_json: Optional[str] = None, section: str = "bench",
              metrics: Optional[Dict] = None, ledger=None) -> List[str]:
    """The one benchmark exit path (telemetry/writer.py owns the
    formats): print the classic ``name,us_per_call,derived`` table,
    side-emit it to ``out`` as a CSV artifact, and — when ``bench_json``
    is given — fold rows + gateable ``metrics`` + a measured telemetry
    ``ledger`` into the versioned ``BENCH_<pr>.json`` section, which
    ``scripts/bench_gate.py`` regression-gates in CI.  Replaces the
    hand-rolled ``lines = [header] + ...`` blocks each bench used to
    carry."""
    from repro.telemetry import writer
    lines = writer.csv_lines(rows)
    print("\n".join(lines), flush=True)
    if out:
        writer.write_csv(out, rows)
    if bench_json:
        writer.merge_section(bench_json, section, rows=rows,
                             metrics=metrics, ledger=ledger)
    return lines


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def brick_bytes_analytic(cfg, quant_labels: Dict[str, str]) -> Dict[str, int]:
    """Per-brick weight bytes for the FULL config under a per-brick
    quantization labelling (no allocation)."""
    from repro.models.model import count_params_analytic
    n_total = count_params_analytic(cfg)
    emb = cfg.padded_vocab * cfg.d_model
    head = 0 if cfg.tie_embeddings else emb
    proj = (cfg.vision_feat_dim * cfg.d_model + cfg.d_model ** 2
            if cfg.vlm else 0)
    body = n_total - emb - head - proj
    params = {"embedding": emb, "decoder": body, "head": head or emb,
              "projector": proj}
    out = {}
    for brick, n in params.items():
        if n == 0:
            continue
        bits = _LABEL_BITS[quant_labels.get(brick, "bf16")]
        out[brick] = int(n * bits / 8)
    return out
