"""Paper Fig. 8: power consumption (W) and hours on a 2000 mAh pack.

The three execution modes, energy-modeled end to end on the edge profiles:

  unconstrained — parallel offloading, camera at 30 FPS (continuous VLM)
  throttled     — alpha-scaled frame rate / memory clock (B = 40%)
  cascade       — event-triggered one-shot inference (paper: 0.375 W,
                  20.8 h); events at the paper's assistant duty cycle

Also derives the paper's headline -42.3% energy vs a monolithic-GPU
deployment at the same workload.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row
from repro.analysis.energy import hours_on_battery
from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.power import PowerPolicy
from repro.core.scheduler import edge_accelerators, schedule

EVENTS_PER_HOUR = 60          # cascade: one wake-word inference / minute
TOKENS_PER_EVENT = 48         # short voice answer
VISION_TOKENS = 729           # SigLip-so400m patches per frame
SIGLIP_PARAMS = 400e6
IDLE_W_STANDBY = 0.35         # A55 core awake + LPDDR self-refresh + PMU
BATTERY_V = 3.9               # paper's 2000mAh pack (20.8h at 0.375W)


def _pipeline(arch="llava-onevision-0.5b"):
    """The paper's full pipeline, including the REAL vision-encoder brick
    (SigLip-so400m-class) the stub frontend stands in for — its placement
    (NPU vs GPU) is where the paper's energy saving comes from."""
    from repro.core.bricks import Brick, Port
    cfg = get_config(arch)
    g = decompose(cfg)
    enc = Brick("vision_encoder", "encoder", (),
                lambda p, c, ctx: ctx["vision_feats"],
                in_ports=(Port("vision_feats"),), out_port=Port("patches"),
                static_shape=True, quant_label="fp16",
                flops_per_token=2 * SIGLIP_PARAMS,
                param_bytes=int(SIGLIP_PARAMS * 2))
    g.bricks = [enc if b.name == "vision_frontend" else b for b in g.bricks]
    g.bricks = [b if b.param_bytes else dataclasses.replace(
        b, param_bytes=int(b.flops_per_token / 2 * 0.56))
        for b in g.bricks]
    return g


def _event_cost(g, placement_accels, brick_tokens):
    """Energy/latency of one inference EVENT (1 frame + a short answer),
    summing per-brick costs at each brick's own token count."""
    from repro.core.scheduler import brick_cost
    e = t = 0.0
    for brick in g.bricks:
        acc = placement_accels[brick.name]
        n = brick_tokens.get(brick.kind, TOKENS_PER_EVENT)
        c = brick_cost(brick, acc, n)
        e, t = e + c.energy_j, t + c.latency_s
    return e, t


def run():
    g = _pipeline()
    accels = edge_accelerators()
    by_name = {a.name: a for a in accels}
    pol = PowerPolicy()
    rows = []

    # per-event token counts per brick kind: one frame through the vision
    # path, TOKENS_PER_EVENT through the language path
    brick_tokens = {"encoder": VISION_TOKENS, "projector": VISION_TOKENS,
                    "embed": TOKENS_PER_EVENT, "decoder": TOKENS_PER_EVENT,
                    "head": TOKENS_PER_EVENT, "frontend": 0}

    # NANOMIND placement (scheduler, energy objective at the event shape)
    pl_e = schedule(g, accels, n_tokens=TOKENS_PER_EVENT, objective="energy")
    nano_acc = {b: by_name[a] for b, a in pl_e.assignment.items()}
    e_nano, t_nano = _event_cost(g, nano_acc, brick_tokens)
    # monolithic baseline: the whole pipeline on the GPU
    mono_acc = {b.name: by_name["gpu"] for b in g.bricks}
    e_mono, t_mono = _event_cost(g, mono_acc, brick_tokens)

    # --- unconstrained: continuous camera VLM ------------------------------
    events_per_s = 1.0                      # 1 frame+answer per second
    w = e_nano * events_per_s + 0.45        # + camera/SoC base
    rows.append(Row("fig8/unconstrained", t_nano * 1e6,
                    f"W={w:.2f} "
                    f"hours={hours_on_battery(w, volts=BATTERY_V):.1f} "
                    f"fps={pol.full_fps:.0f} E/event={e_nano:.2f}J"))

    # --- throttled at B=40%: alpha-scaled ----------------------------------
    knobs = pol.knobs(0.4)
    w_t = (e_nano * events_per_s * knobs.admission_rate
           + 0.45 * knobs.mem_clock_scale)
    rows.append(Row("fig8/throttled(B=40%)", t_nano * 1e6,
                    f"W={w_t:.2f} "
                    f"hours={hours_on_battery(w_t, volts=BATTERY_V):.1f} "
                    f"alpha={pol.alpha(0.4):.2f} "
                    f"fps={knobs.frame_rate_hz:.0f}"))

    # --- cascade: event-triggered one-shot ---------------------------------
    w_c = IDLE_W_STANDBY + e_nano * EVENTS_PER_HOUR / 3600.0
    rows.append(Row("fig8/cascade", 0.0,
                    f"W={w_c:.3f} "
                    f"hours={hours_on_battery(w_c, volts=BATTERY_V):.1f} "
                    f"events/h={EVENTS_PER_HOUR} "
                    f"(paper: 0.375W / 20.8h)"))

    # --- headline: energy vs monolithic-GPU --------------------------------
    saving = 1 - e_nano / e_mono
    rows.append(Row("fig8/energy-vs-monolithic", 0.0,
                    f"nanomind={e_nano:.2f}J/event "
                    f"monolithic-gpu={e_mono:.2f}J/event "
                    f"saving={saving:.1%} (paper: 42.3%) "
                    f"placement={pl_e.assignment}"))
    return rows
