"""Render the §Roofline table from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(out_dir="experiments/dryrun", mesh="16x16"):
    recs = []
    for fn in glob.glob(os.path.join(out_dir, f"*__{mesh}.json")):
        with open(fn) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return recs


def table_lines(mesh="16x16"):
    lines = ["| arch | shape | t_comp | t_mem | t_coll | bound | useful | "
             "roofline | mem/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh=mesh):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f}ms | "
            f"{r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.1%} | "
            f"{r['roofline_fraction']:.2%} | "
            f"{(r.get('memory_per_device') or 0)/1e9:.1f}GB |")
    return lines


def run():
    rows = []
    for r in load():
        if r.get("status") != "ok":
            continue
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["t_compute_s"], r["t_memory_s"],
                r["t_collective_s"]) * 1e6,
            f"bound={r['bottleneck']} useful={r['useful_flops_ratio']:.1%} "
            f"roofline={r['roofline_fraction']:.2%}"))
    return rows


if __name__ == "__main__":
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print("\n".join(table_lines(mesh)))
