"""Paper Fig. 5: memory utilization across frameworks x small VLMs.

Frameworks compared (same accounting, different mechanisms):
  llama.cpp-style  — fp16 weights monolithic + per-module staging buffers
                     (separate-memory design on a UMA device)
  nanomind         — hybrid quant (vis fp16 / dec W4A16) + TABM ring pool,
                     no staging copies

Models: the paper's trio (LLaVA-OneVision-0.5B, Qwen2-VL, SmolVLM-class —
we map SmolVLM to the stablelm-1.6b-backbone scale).  The paper's headline:
NANOMIND cuts GPU memory ~11.2%; our mechanism-level accounting lands in
the same band (derived column reports the delta).
"""
from __future__ import annotations

from benchmarks.common import Row, brick_bytes_analytic
from repro.configs import get_config

KV_TOKENS = 2048        # serving context per the paper's tests
BATCH = 1


def _kv_bytes(cfg, tokens=KV_TOKENS):
    return (cfg.n_layers * BATCH * tokens * cfg.n_kv_heads * cfg.hd * 2
            * 2)


GGML_GRAPH_NODES = 32   # ggml schedules per-node arenas on BOTH backends


def llama_cpp_bytes(cfg):
    """Monolithic separate-memory design: fp16 weights + ggml-style
    per-backend compute arenas (the CPU keeps staging copies of every
    offloaded node's I/O — Fig. 9's 'CPU must continuously write to
    buffers and maintain separate memory allocation')."""
    w = brick_bytes_analytic(cfg, {"decoder": "fp16", "embedding": "fp16",
                                   "head": "fp16", "projector": "fp16"})
    act = BATCH * KV_TOKENS * cfg.d_model * 2
    staging = GGML_GRAPH_NODES * act
    return sum(w.values()) + _kv_bytes(cfg) + staging


def nanomind_bytes(cfg):
    w = brick_bytes_analytic(cfg, {"decoder": "q4f16", "embedding": "fp16",
                                   "head": "q4f16", "projector": "fp16"})
    ring = 4 * (cfg.vision_tokens or 64) * cfg.d_model * 2   # TABM pool
    return sum(w.values()) + _kv_bytes(cfg) + ring


def nanomind_fp16_bytes(cfg):
    """Ablation: TABM only, no quantization — isolates the ring-buffer
    saving (the paper's -11.2% is at matched precision)."""
    w = brick_bytes_analytic(cfg, {"decoder": "fp16", "embedding": "fp16",
                                   "head": "fp16", "projector": "fp16"})
    ring = 4 * (cfg.vision_tokens or 64) * cfg.d_model * 2
    return sum(w.values()) + _kv_bytes(cfg) + ring


def run():
    rows = []
    for arch in ("llava-onevision-0.5b", "qwen2-vl-7b", "stablelm-1.6b"):
        cfg = get_config(arch)
        base = llama_cpp_bytes(cfg)
        ring_only = nanomind_fp16_bytes(cfg)
        full = nanomind_bytes(cfg)
        rows.append(Row(f"fig5/llama.cpp/{arch}", 0.0,
                        f"mem={base/1e9:.3f}GB"))
        rows.append(Row(f"fig5/nanomind-fp16/{arch}", 0.0,
                        f"mem={ring_only/1e9:.3f}GB "
                        f"delta={(ring_only-base)/base:+.1%} (TABM only)"))
        rows.append(Row(f"fig5/nanomind/{arch}", 0.0,
                        f"mem={full/1e9:.3f}GB "
                        f"delta={(full-base)/base:+.1%} (TABM + hybrid W4)"))
    return rows
