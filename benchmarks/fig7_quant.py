"""Paper Fig. 7: hybrid-quantization configurations x task accuracy.

The paper's finding: with the model decomposed, *vision-task* accuracy is
dominated by the ViT's precision; the decoder tolerates 4-bit.  We
reproduce the structure with a briefly-trained tiny VLM (synthetic data):

* train a reduced llava-style model until it beats chance;
* evaluate every Fig.-7 profile on (a) vision-conditioned and (b)
  text-only batches, scoring top-1 agreement with the fp16 model;
* the derived column shows the paper's ordering: dec-q4 is nearly free,
  vis-q4 costs vision-task agreement specifically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.quantize import PROFILES, dequantize_tree, quantize_tree
from repro.data import multimodal_batch_iter
from repro.models.model import lm_forward
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, fit


def _degradation(cfg, params_a, params_b, batch):
    """(KL(fp16 || quant), top1 agreement) — KL is the sensitive probe;
    agreement is the task-level one."""
    la, _ = lm_forward(params_a, cfg, batch["tokens"],
                       vision_feats=batch.get("vision_feats"))
    lb, _ = lm_forward(params_b, cfg, batch["tokens"],
                       vision_feats=batch.get("vision_feats"))
    v = cfg.vocab_size
    pa = jax.nn.log_softmax(la[..., :v], -1)
    pb = jax.nn.log_softmax(lb[..., :v], -1)
    kl = float(jnp.mean(jnp.sum(jnp.exp(pa) * (pa - pb), -1)))
    agree = float(jnp.mean((jnp.argmax(la, -1) == jnp.argmax(lb, -1))
                           .astype(jnp.float32)))
    return kl, agree


N_CLASSES = 32
SIGNAL = 0.5          # class-feature bump: moderate, so quantization noise
NOISE = 0.25          # competes with it (the Fig.-7 sensitivity regime)
ANSWER_SPAN = 4


def _vision_task_batch(cfg, rng, batch=8, seq=64):
    """A toy 'classify the image' task whose answer DEPENDS on the image:
    the image carries a class-coded feature bump over noise; the text span
    after the image must name the class.  Random-noise feats would be
    ignored by the decoder — this is what makes ViT precision matter."""
    vt = cfg.vision_tokens
    feats = (rng.standard_normal((batch, vt, cfg.vision_feat_dim))
             * NOISE).astype(np.float32)
    cls = rng.integers(0, N_CLASSES, batch)
    for b in range(batch):
        feats[b, :, cls[b]] += SIGNAL
    tokens = np.zeros((batch, seq), np.int64)
    tokens[:, :vt] = 2                                  # image placeholders
    tokens[:, vt:vt + ANSWER_SPAN] = (cls + 3)[:, None]
    return {"tokens": jnp.asarray(tokens, jnp.int32),
            "vision_feats": jnp.asarray(feats)}


def run():
    cfg = get_config("llava-onevision-0.5b").reduced()
    from repro.launch.steps import init_params
    from repro.training.optimizer import init_opt
    from repro.training.train_loop import build_accum_train_step
    params = init_params(jax.random.PRNGKey(0), cfg)
    oc = OptConfig(lr=2e-3, warmup_steps=5, total_steps=250)
    opt = init_opt(params, oc)
    step = jax.jit(build_accum_train_step(cfg, oc, 1))
    rng = np.random.default_rng(0)
    loss0 = lossN = None
    for i in range(250):
        batch = _vision_task_batch(cfg, rng)
        params, opt, m = step(params, opt, batch)
        loss0 = loss0 if loss0 is not None else float(m["loss"])
        lossN = float(m["loss"])

    rng = np.random.default_rng(7)
    vis_batch = _vision_task_batch(cfg, rng)
    txt_batch = {"tokens": vis_batch["tokens"]}

    def task_acc(p):
        """Accuracy on the class-naming span (the 'vision task')."""
        vt = cfg.vision_tokens
        accs = []
        for trial in range(4):                 # fresh eval images
            b = _vision_task_batch(cfg, np.random.default_rng(100 + trial))
            logits, _ = lm_forward(p, cfg, b["tokens"],
                                   vision_feats=b["vision_feats"])
            pred = jnp.argmax(logits[:, vt - 1], -1)
            gold = b["tokens"][:, vt]
            accs.append(float(jnp.mean((pred == gold)
                                       .astype(jnp.float32))))
        return float(np.mean(accs))

    rows = [Row("fig7/train-proxy", 0.0,
                f"loss {loss0:.2f}->{lossN:.2f} "
                f"fp16_task_acc={task_acc(params):.3f} "
                f"(tiny llava-style model, vision-describe task)")]
    for prof in ("all-fp16", "nanomind-default", "dec-q8", "vis-q4",
                 "dec-q2", "all-q4"):
        qp = dequantize_tree(quantize_tree(params, PROFILES[prof]))
        vkl, _ = _degradation(cfg, params, qp, vis_batch)
        rows.append(Row(
            f"fig7/{prof}", 0.0,
            f"vision_task_acc={task_acc(qp):.3f} KL_vs_fp16={vkl:.4f}"))
    return rows
