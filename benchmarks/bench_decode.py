"""Decode-throughput evidence for the cohort step over the paged pool.

The engine's decode is ONE batched jit call over every in-flight
request (``ServingEngine._cohort_fn``): each row gathers its context
through its KV block table, decodes independently, and scatters its new
K/V back into its granted blocks.  This microbenchmark measures decode
tokens/s with the same four requests in flight at cohort size 1 (the
``max_cohort=1`` rotating window — one request decodes per step, the
un-batched baseline) vs cohort size 4 (all rows ride one step) on CPU
JAX.  The win is amortization: one dispatch, one weight pass, and one
donated pool update serve four rows instead of one.

Second axis: the fused cohort step (``kernels/fused_decode``) vs the
composed three-dispatch path, both over W4A16 params.  Wall-clock
tokens/s for both are recorded ungated — on CPU the fused path runs
pallas *interpret* mode, which is bit-identical but slow, so the CI
gate is the MODELED per-step HBM weight-traffic ratio instead: the
composed path reads each packed QTensor, materializes the dense fp16
weight in HBM, and reads it back into the GEMM (packed + 2x dense);
the fused kernel unpacks in VMEM and never round-trips the dense
weight (packed only).  Weights both paths treat identically (wo, norms,
embedding, head) are excluded — the ratio covers exactly the
qkv/mlp weights the kernels fuse.

    python -m benchmarks.bench_decode [--smoke] [--out CSV]

``--smoke`` gates (exit 1) on cohort 4 reaching >= 2x the cohort-1
decode tokens/s — the CI check that continuous batching stays a real
speedup, not just a code path — and on the fused step's modeled HBM
weight traffic staying strictly below the composed path's.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Row, emit_rows

COHORTS = (1, 4)
N_LIVE = 4
GATE = 2.0
# the kernel fuses exactly these per-layer weights (ops.py consumes them
# packed); everything else is dequantized identically on both paths
FUSED_WEIGHTS = ("wq", "wk", "wv", "w_up", "w_down", "w_gate")
# fused interpret-mode steps are slow on CPU; the tokens/s row only
# needs a stable steady-state mean, not the composed path's iteration
# count
FUSED_ITERS_CAP = 12


def _setup():
    from repro.configs import get_config
    from repro.launch.steps import init_params

    cfg = get_config("stablelm-1.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def modeled_weight_traffic(layers) -> tuple:
    """Modeled per-decode-step HBM weight bytes over the stacked layer
    params: ``(composed, fused)``.

    For each packed :class:`~repro.core.quantize.QTensor` the composed
    path costs ``packed + 2 * dense_fp16`` (read codes+scales, write the
    dequantized dense weight, read it back into the GEMM) while the
    fused kernel costs ``packed`` (in-VMEM unpack).  Dense leaves cost
    one read either way.  Only the weights the kernel actually fuses
    (``FUSED_WEIGHTS``) diverge; shared leaves (wo, norms) are excluded
    so the ratio is exactly the fusion's claim, not diluted or inflated
    by traffic both paths share."""
    from repro.core.quantize import QTensor

    composed = fused = 0
    is_q = lambda x: isinstance(x, QTensor)
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            layers, is_leaf=is_q)[0]:
        keys = [getattr(p, "key", None) for p in path]
        if not any(k in FUSED_WEIGHTS for k in keys):
            continue
        if is_q(leaf):
            packed = leaf.nbytes
            dense16 = int(np.prod(leaf.shape)) * 2
            composed += packed + 2 * dense16
            fused += packed
        elif hasattr(leaf, "nbytes"):
            composed += int(leaf.nbytes)
            fused += int(leaf.nbytes)
    return composed, fused


def _decode_rate(cfg, params, max_cohort, iters: int, use_fused=None):
    """Tokens/s of the steady-state decode loop with N_LIVE requests in
    flight (spares queued so a retirement refills the cohort); also
    returns the engine's measured telemetry ledger (prefill + decode
    wall-time spans)."""
    from repro.serving.engine import Request, ServingEngine

    with ServingEngine(cfg, params, n_slots=N_LIVE, max_len=128,
                       max_cohort=max_cohort, use_fused=use_fused) as eng:
        for i in range(N_LIVE * 8):            # spares keep the pool full
            eng.submit(Request(
                rid=i, tokens=(np.arange(6 + i % 5) % 50 + 3).astype(
                    np.int32),
                max_new_tokens=100_000))
        for _ in range(4):                     # warmup: prefill + cohort jit
            eng.step()
        jax.block_until_ready(eng.slots.pool)
        before = eng.stats.decoded_tokens
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.step()
        jax.block_until_ready(eng.slots.pool)
        dt = time.perf_counter() - t0
        return (eng.stats.decoded_tokens - before) / dt, \
            eng.measured_ledger()


def run_bench(iters: int):
    cfg, params = _setup()
    ledger = None
    rates = {}
    for c in COHORTS:
        rates[c], led = _decode_rate(cfg, params, c, iters)
        ledger = led if ledger is None else ledger.merge(led)
    rows = [
        Row(f"decode/cohort/B={c}", 0.0,
            f"decode_tokens_per_s={rates[c]:.1f} live={N_LIVE} "
            f"iters={iters}")
        for c in COHORTS
    ]
    ratio = rates[COHORTS[-1]] / max(rates[COHORTS[0]], 1e-9)
    rows.append(Row("decode/cohort/speedup", 0.0,
                    f"B{COHORTS[-1]}_over_B{COHORTS[0]}={ratio:.2f}x "
                    f"(one batched step + one donated paged-pool update "
                    f"serve the whole cohort)"))

    # fused vs composed cohort step over W4A16 params (same cohort size,
    # same requests, same paged pool geometry)
    from repro.core.quantize import PROFILES, quantize_tree
    qparams = quantize_tree(params, PROFILES["nanomind-serve"])
    f_iters = min(iters, FUSED_ITERS_CAP)
    composed_q, led_c = _decode_rate(cfg, qparams, COHORTS[-1], f_iters,
                                     use_fused=False)
    fused_q, led_f = _decode_rate(cfg, qparams, COHORTS[-1], f_iters,
                                  use_fused=True)
    ledger = ledger.merge(led_c).merge(led_f)
    hbm_composed, hbm_fused = modeled_weight_traffic(qparams["layers"])
    hbm_ratio = hbm_composed / max(hbm_fused, 1)
    rows.append(Row(
        f"decode/fused/B={COHORTS[-1]}", 0.0,
        f"fused_tokens_per_s={fused_q:.1f} composed={composed_q:.1f} "
        f"iters={f_iters} (CPU runs the kernels in pallas interpret "
        f"mode: bit-identical, not representative wall-clock)"))
    rows.append(Row(
        "decode/fused/hbm_weight_traffic", 0.0,
        f"composed={hbm_composed}B fused={hbm_fused}B "
        f"ratio={hbm_ratio:.2f}x per step (packed + 2x dense fp16 "
        f"round-trip vs packed-only in-VMEM unpack)"))
    fused = {"tokens_per_s": fused_q, "composed_tokens_per_s": composed_q,
             "hbm_ratio": hbm_ratio}
    return rows, rates, ratio, fused, ledger


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="cohort 1 vs 4 decode throughput over the paged pool")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI mode: fewer iterations, gate on cohort 4 "
                         f">= {GATE}x cohort 1")
    ap.add_argument("--iters", type=int, default=None,
                    help="decode steps per cohort size (default 80; 30 "
                         "under --smoke)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this path (CI "
                         "artifact)")
    ap.add_argument("--bench-json", default=None,
                    help="fold rows/metrics/measured ledger into this "
                         "versioned BENCH_<pr>.json (shared telemetry "
                         "writer)")
    args = ap.parse_args(argv)
    iters = args.iters or (30 if args.smoke else 80)
    rows, rates, ratio, fused, ledger = run_bench(iters)
    from repro.telemetry.writer import metric
    emit_rows(
        rows, out=args.out, bench_json=args.bench_json, section="decode",
        metrics={
            # wall-clock throughputs are machine-dependent: recorded for
            # the trajectory, not CI-gated (the >= GATE smoke below is
            # the real regression check for cohort batching)
            f"decode_tokens_per_s_b{c}": metric(rates[c], gate=False)
            for c in COHORTS} | {
            "decode_speedup_b4_over_b1": metric(ratio, gate=False),
            # fused wall-clock is interpret-mode on CPU — recorded, not
            # gated; the machine-independent fusion claim (composed HBM
            # weight traffic over fused) is what CI regresses on
            "decode_fused_tokens_per_s": metric(
                fused["tokens_per_s"], gate=False),
            "decode_composed_q4_tokens_per_s": metric(
                fused["composed_tokens_per_s"], gate=False),
            "decode_fused_hbm_traffic_ratio": metric(
                fused["hbm_ratio"], better="higher")},
        ledger=ledger)
    if args.smoke and ratio < GATE:            # gate, not just a report
        print(f"FAIL: cohort decode is not >= {GATE}x "
              f"(B4/B1 = {ratio:.2f}x)")
        return 1
    if args.smoke and fused["hbm_ratio"] <= 1.0:
        print(f"FAIL: fused step does not move fewer modeled HBM weight "
              f"bytes than composed (ratio {fused['hbm_ratio']:.2f}x)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
