"""Decode-throughput evidence for the cohort step over the paged pool.

The engine's decode is ONE batched jit call over every in-flight
request (``ServingEngine._cohort_fn``): each row gathers its context
through its KV block table, decodes independently, and scatters its new
K/V back into its granted blocks.  This microbenchmark measures decode
tokens/s with the same four requests in flight at cohort size 1 (the
``max_cohort=1`` rotating window — one request decodes per step, the
un-batched baseline) vs cohort size 4 (all rows ride one step) on CPU
JAX.  The win is amortization: one dispatch, one weight pass, and one
donated pool update serve four rows instead of one.

    python -m benchmarks.bench_decode [--smoke] [--out CSV]

``--smoke`` gates (exit 1) on cohort 4 reaching >= 2x the cohort-1
decode tokens/s — the CI check that continuous batching stays a real
speedup, not just a code path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Row, emit_rows

COHORTS = (1, 4)
N_LIVE = 4
GATE = 2.0


def _setup():
    from repro.configs import get_config
    from repro.launch.steps import init_params

    cfg = get_config("stablelm-1.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _decode_rate(cfg, params, max_cohort, iters: int):
    """Tokens/s of the steady-state decode loop with N_LIVE requests in
    flight (spares queued so a retirement refills the cohort); also
    returns the engine's measured telemetry ledger (prefill + decode
    wall-time spans)."""
    from repro.serving.engine import Request, ServingEngine

    with ServingEngine(cfg, params, n_slots=N_LIVE, max_len=128,
                       max_cohort=max_cohort) as eng:
        for i in range(N_LIVE * 8):            # spares keep the pool full
            eng.submit(Request(
                rid=i, tokens=(np.arange(6 + i % 5) % 50 + 3).astype(
                    np.int32),
                max_new_tokens=100_000))
        for _ in range(4):                     # warmup: prefill + cohort jit
            eng.step()
        jax.block_until_ready(eng.slots.pool)
        before = eng.stats.decoded_tokens
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.step()
        jax.block_until_ready(eng.slots.pool)
        dt = time.perf_counter() - t0
        return (eng.stats.decoded_tokens - before) / dt, \
            eng.measured_ledger()


def run_bench(iters: int):
    cfg, params = _setup()
    ledger = None
    rates = {}
    for c in COHORTS:
        rates[c], led = _decode_rate(cfg, params, c, iters)
        ledger = led if ledger is None else ledger.merge(led)
    rows = [
        Row(f"decode/cohort/B={c}", 0.0,
            f"decode_tokens_per_s={rates[c]:.1f} live={N_LIVE} "
            f"iters={iters}")
        for c in COHORTS
    ]
    ratio = rates[COHORTS[-1]] / max(rates[COHORTS[0]], 1e-9)
    rows.append(Row("decode/cohort/speedup", 0.0,
                    f"B{COHORTS[-1]}_over_B{COHORTS[0]}={ratio:.2f}x "
                    f"(one batched step + one donated paged-pool update "
                    f"serve the whole cohort)"))
    return rows, rates, ratio, ledger


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="cohort 1 vs 4 decode throughput over the paged pool")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI mode: fewer iterations, gate on cohort 4 "
                         f">= {GATE}x cohort 1")
    ap.add_argument("--iters", type=int, default=None,
                    help="decode steps per cohort size (default 80; 30 "
                         "under --smoke)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this path (CI "
                         "artifact)")
    ap.add_argument("--bench-json", default=None,
                    help="fold rows/metrics/measured ledger into this "
                         "versioned BENCH_<pr>.json (shared telemetry "
                         "writer)")
    args = ap.parse_args(argv)
    iters = args.iters or (30 if args.smoke else 80)
    rows, rates, ratio, ledger = run_bench(iters)
    from repro.telemetry.writer import metric
    emit_rows(
        rows, out=args.out, bench_json=args.bench_json, section="decode",
        metrics={
            # wall-clock throughputs are machine-dependent: recorded for
            # the trajectory, not CI-gated (the >= GATE smoke below is
            # the real regression check for cohort batching)
            f"decode_tokens_per_s_b{c}": metric(rates[c], gate=False)
            for c in COHORTS} | {
            "decode_speedup_b4_over_b1": metric(ratio, gate=False)},
        ledger=ledger)
    if args.smoke and ratio < GATE:            # gate, not just a report
        print(f"FAIL: cohort decode is not >= {GATE}x "
              f"(B4/B1 = {ratio:.2f}x)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
