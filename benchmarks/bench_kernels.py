"""Kernel-level evidence for the paper's fused dequant-GEMM claim:

"Avoid separate dequant passes to cut memory traffic and keep the pipeline
saturated" — we compile (a) the fused form (dequant feeding the matmul, as
the Pallas kernel computes and as XLA fuses the ref) and (b) an explicit
two-pass form (materialize the fp16 weight matrix to memory, then matmul),
and compare HLO traffic via the trip-count-aware cost model, plus CPU wall
time of the jnp paths and the interpret-mode kernel allclose residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, emit_rows, timeit
from repro.analysis import hlo_cost
from repro.core.quantize import QuantSpec, dequantize, quantize
from repro.kernels.dequant_gemm import dequant_gemm, ref_dequant_gemm

M, K, N = 256, 4096, 4096
# CI smoke shapes: tiny but still a multiple of the q4 group size (64)
# and of the kernel's BlockSpec tiles, so every code path is exercised
SMOKE_M, SMOKE_K, SMOKE_N = 128, 512, 256


def run(m: int = M, k: int = K, n: int = N):
    """CSV rows for benchmarks.run."""
    return _bench(m, k, n)[0]


def _bench(m: int, k: int, n: int):
    """Returns (rows, rel_err, traffic_ratio) — the numeric residual is
    what the CI smoke gates on, independent of row order or label
    wording; the analytic two-pass/fused HBM-traffic ratio is
    deterministic (pure shape arithmetic) and regression-gated through
    BENCH_<pr>.json."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32).astype(jnp.bfloat16)
    w = (jax.random.normal(key, (n, k), jnp.float32) * 0.05
         ).astype(jnp.bfloat16)
    qt = quantize(w, QuantSpec(4))

    fused = jax.jit(lambda x, q: ref_dequant_gemm(x, q))
    two_pass = jax.jit(lambda x, q: jnp.einsum(
        "mk,nk->mn", x, jax.lax.optimization_barrier(dequantize(q)),
        preferred_element_type=jnp.float32).astype(x.dtype))

    us_f = timeit(fused, x, qt)
    us_t = timeit(two_pass, x, qt)

    from repro.kernels.dequant_gemm.ops import resolve_use_kernel
    path = "kernel" if resolve_use_kernel(qt, None) else "ref"
    out_k = dequant_gemm(x, qt, interpret=True, bm=128)
    res = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                - fused(x, qt).astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(fused(x, qt).astype(jnp.float32))))

    # odd-K regression: a K that is NOT a tile multiple must still resolve
    # to the kernel path (ops pads K internally) and match the reference
    ko = k - 63
    xo = x[:, :ko]
    qto = quantize(w[:, :ko], QuantSpec(4))
    path_odd = "kernel" if resolve_use_kernel(qto, None) else "ref"
    out_o = dequant_gemm(xo, qto, interpret=True, bm=128).astype(jnp.float32)
    ref_o = ref_dequant_gemm(xo, qto).astype(jnp.float32)
    res_o = float(jnp.max(jnp.abs(out_o - ref_o)))
    scale_o = float(jnp.max(jnp.abs(ref_o)))

    # analytic HBM traffic on the TPU target (what the BlockSpecs imply):
    # fused   : x + packed codes + scales + out  (weight tile unpacks in VMEM)
    # two-pass: + bf16 W written AND re-read through HBM
    t_x, t_out = m * k * 2, m * n * 2
    t_codes = n * k // 2 + n * (k // 64) * 4
    t_fused = t_x + t_codes + t_out
    t_two = t_fused + 2 * n * k * 2

    rows = [
        Row("kernels/dequant_gemm/fused", us_f,
            f"hbm_traffic={t_fused/1e6:.1f}MB (codes stream once, unpack "
            f"in VMEM; wall-time is CPU-XLA)"),
        Row("kernels/dequant_gemm/two-pass", us_t,
            f"hbm_traffic={t_two/1e6:.1f}MB "
            f"(+{(t_two-t_fused)/t_fused:.0%} — the separate dequant pass "
            f"the paper eliminates)"),
        Row("kernels/dequant_gemm/pallas-interpret", 0.0,
            f"rel_err_vs_ref={res/scale:.2e} path={path} "
            f"(BlockSpec 128x128x512, fp32 acc)"),
        Row("kernels/dequant_gemm/pallas-odd-k", 0.0,
            f"rel_err_vs_ref={res_o/scale_o:.2e} path={path_odd} "
            f"(K={ko} padded to the tile inside ops)"),
    ]
    rel = max(res / scale, res_o / scale_o)
    return rows, rel, t_two / t_fused


def main(argv=None) -> int:
    """Standalone entry so CI can gate on the kernel benchmark without the
    full ``benchmarks.run`` matrix:

        python -m benchmarks.bench_kernels --smoke
    """
    import argparse
    ap = argparse.ArgumentParser(
        description="fused dequant-GEMM kernel benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI shapes (seconds, not minutes) — still "
                         "compiles both forms and checks the interpret-"
                         "mode kernel residual")
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this path (CI "
                         "artifact)")
    ap.add_argument("--bench-json", default=None,
                    help="fold rows/metrics into this versioned "
                         "BENCH_<pr>.json (shared telemetry writer)")
    args = ap.parse_args(argv)
    rows, rel, traffic = _bench(*((SMOKE_M, SMOKE_K, SMOKE_N) if args.smoke
                                  else (M, K, N)))
    from repro.telemetry.writer import metric
    emit_rows(
        rows, out=args.out, bench_json=args.bench_json, section="kernels",
        metrics={
            # analytic shape arithmetic — deterministic, so gateable
            "fused_hbm_traffic_ratio": metric(traffic, better="higher",
                                              gate=True),
            "kernel_rel_err": metric(rel, better="lower", gate=False)})
    if args.smoke and rel > 1e-2:              # gate, not just a report
        print(f"FAIL: kernel residual {rel} too large")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
