"""Paper Table 1: resource utilization vs layers offloaded to the GPU.

The paper profiles llama.cpp's layer-offload mechanism: as layers move to
the GPU, *total memory grows* (CPU staging buffers + duplicated tensors)
while CPU stays busy shuttling buffers.  We reproduce the mechanism with
the cost model: a monolithic runtime that stages every offloaded layer's
I/O through host memory, vs NANOMIND's zero-copy placement.

derived column: host_GB | accel_GB | cpu_util | accel_util
"""
from __future__ import annotations

from benchmarks.common import Row, brick_bytes_analytic
from repro.configs import get_config


def llama_cpp_style(cfg, n_layers_offloaded: int):
    """The paper's Table-1 baseline: per-layer weights move to the GPU but
    every offloaded layer keeps a CPU-side staging copy of activations and
    the CPU drives each transfer (GGML_BACKEND_GPU flow, Fig. 9)."""
    total_layers = cfg.n_layers
    frac = n_layers_offloaded / total_layers
    w = brick_bytes_analytic(cfg, {"decoder": "q4f16", "embedding": "fp16",
                                   "head": "q4f16"})
    body = w["decoder"]
    host_bytes = w["embedding"] + w["head"] + body * (1 - frac)
    accel_bytes = body * frac
    # staging: activations ping-pong per offloaded layer (B=1, S=512)
    act = 512 * cfg.d_model * 2
    staging = n_layers_offloaded * act * 2          # in + out copies
    host_bytes += staging
    cpu_util = 0.5 if frac == 0 else 0.37 + 0.01 * (1 - frac)
    gpu_util = min(0.99, frac * 1.1)
    return host_bytes, accel_bytes, cpu_util, gpu_util


def nanomind_style(cfg):
    """Module-level placement + TABM: no staging copies, one ring buffer."""
    w = brick_bytes_analytic(cfg, {"decoder": "q4f16", "embedding": "fp16",
                                   "head": "q4f16", "projector": "fp16"})
    ring = 4 * 512 * cfg.d_model * 2                # 4-slot TABM pool
    host = w["embedding"]                           # control plane only
    accel = sum(v for k, v in w.items() if k != "embedding") + ring
    return host, accel, 0.12, 0.95


def run():
    rows = []
    for arch, layers in (("stablelm-1.6b", (0, 10, 24)),
                         ("deepseek-moe-16b", (0, 10, 28))):
        cfg = get_config(arch)
        for n in layers:
            h, a, cu, gu = llama_cpp_style(cfg, n)
            rows.append(Row(
                f"table1/llama.cpp/{arch}/gpu_layers={n}", 0.0,
                f"host={h/1e9:.2f}GB accel={a/1e9:.2f}GB cpu={cu:.0%} "
                f"gpu={gu:.0%} total={(h+a)/1e9:.2f}GB"))
        h, a, cu, gu = nanomind_style(cfg)
        base_total = sum(llama_cpp_style(cfg, layers[-1])[:2])
        rows.append(Row(
            f"table1/nanomind/{arch}/module-placement", 0.0,
            f"host={h/1e9:.2f}GB accel={a/1e9:.2f}GB cpu={cu:.0%} "
            f"gpu={gu:.0%} total={(h+a)/1e9:.2f}GB "
            f"vs_llamacpp={-(1-(h+a)/base_total):+.1%}"))
    return rows
