"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig8] [--out CSV]

Prints ``name,us_per_call,derived`` CSV rows through the shared
telemetry writer (``benchmarks.common.emit_rows``), optionally
side-emitting them as one CSV artifact.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import Row, emit_rows

MODULES = {
    "table1": "benchmarks.table1_offload",
    "fig5": "benchmarks.fig5_memory",
    "fig6": "benchmarks.fig6_throughput",
    "fig7": "benchmarks.fig7_quant",
    "fig8": "benchmarks.fig8_power",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.roofline_table",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this path (CI "
                         "artifact)")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else list(MODULES))

    import importlib
    rows = []
    failures = 0
    for name in names:
        try:
            mod = importlib.import_module(MODULES[name])
            rows.extend(mod.run())
        except Exception:
            failures += 1
            rows.append(Row(name, 0.0,
                            f"ERROR: {traceback.format_exc(limit=2)!r}"))
    emit_rows(rows, out=args.out)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
