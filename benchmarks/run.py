"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig8]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = {
    "table1": "benchmarks.table1_offload",
    "fig5": "benchmarks.fig5_memory",
    "fig6": "benchmarks.fig6_throughput",
    "fig7": "benchmarks.fig7_quant",
    "fig8": "benchmarks.fig8_power",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.roofline_table",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else list(MODULES))

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            mod = importlib.import_module(MODULES[name])
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR: {traceback.format_exc(limit=2)!r}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
