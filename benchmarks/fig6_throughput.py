"""Paper Fig. 6: throughput (tokens/s) and end-to-end latency.

Two layers of evidence:
1. MEASURED: the real ServingEngine on the reduced llava config on this
   container's CPU — continuous batching vs one-request-at-a-time, with
   wall-clock tokens/s and per-request e2e latency.  (Absolute numbers are
   CPU-bound; the comparison structure mirrors the figure.)
2. MODELED: the scheduler cost model at FULL scale on the paper's edge
   profiles — monolithic-GPU vs NANOMIND placement for the paper's
   Qwen2-VL-2B-class workload, reproducing the figure's ranking
   (nanomind ~ Jetson-class despite weaker silicon).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.scheduler import (edge_accelerators, populate_brick_bytes,
                                  schedule)
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine


def measured_engine():
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    def submit_all(eng, n):
        for i in range(n):
            eng.submit(Request(
                rid=i, tokens=rng.integers(3, 400, 24).astype(np.int32),
                max_new_tokens=8,
                vision_feats=rng.standard_normal(
                    (1, cfg.vision_tokens, cfg.vision_feat_dim)
                ).astype(np.float32) * 0.02))

    rows = []
    for mode, slots in (("continuous-batch", 4), ("sequential", 1)):
        eng = ServingEngine(cfg, params, n_slots=slots, max_len=256)
        submit_all(eng, 6)
        t0 = time.time()
        done = eng.run()
        wall = time.time() - t0
        lat = [r.e2e_latency for r in done]
        rows.append(Row(
            f"fig6/measured/{mode}", wall * 1e6 / max(1, len(done)),
            f"tok/s={eng.stats.decoded_tokens/wall:.1f} "
            f"e2e_mean={np.mean(lat):.2f}s p95={np.percentile(lat,95):.2f}s"))
    return rows


def modeled_edge():
    """Full-scale LLaVA-OneVision-class pipeline (REAL SigLip-class encoder
    brick included) on the paper's RK3566 profiles — per-event end-to-end
    latency (image + 48-token answer), the figure's metric."""
    from benchmarks.fig8_power import (TOKENS_PER_EVENT, VISION_TOKENS,
                                       _event_cost, _pipeline)
    g = _pipeline()
    accels = edge_accelerators()
    by_name = {a.name: a for a in accels}
    brick_tokens = {"encoder": VISION_TOKENS, "projector": VISION_TOKENS,
                    "embed": TOKENS_PER_EVENT, "decoder": TOKENS_PER_EVENT,
                    "head": TOKENS_PER_EVENT, "frontend": 0}
    rows = []
    for unit in ("gpu", "cpu"):
        acc = {b.name: by_name[unit] for b in g.bricks}
        e, t = _event_cost(g, acc, brick_tokens)
        rows.append(Row(
            f"fig6/modeled/monolithic-{unit}", t * 1e6,
            f"e2e={t:.2f}s tok/s={TOKENS_PER_EVENT/t:.1f} E={e:.2f}J"))
    nano = schedule(g, accels, n_tokens=TOKENS_PER_EVENT,
                    objective="latency")
    acc = {b: by_name[a] for b, a in nano.assignment.items()}
    e, t = _event_cost(g, acc, brick_tokens)
    mono_t = rows[0].us_per_call / 1e6
    rows.append(Row(
        f"fig6/modeled/nanomind", t * 1e6,
        f"e2e={t:.2f}s tok/s={TOKENS_PER_EVENT/t:.1f} E={e:.2f}J "
        f"latency_vs_mono-gpu={t/mono_t-1:+.1%} "
        f"(paper: -36.2% vs rkllm) placement={nano.assignment}"))
    return rows


def run():
    return measured_engine() + modeled_edge()
