"""Staging-throughput evidence for the batched TABM slab pipeline.

The paper's TABM exists to keep encoder -> projector -> hand-off off the
critical path; PR 5 batches it.  This microbenchmark measures the staged
vision-token throughput of ``ExecutionPlan.produce_many`` at K=1 (the old
one-request-per-commit pipeline) vs K=4 (one batched projector call + one
strided slab commit for four same-class requests) on CPU JAX.  The win is
amortization: one jit dispatch, one donated pool scatter, and one pass of
host-side ring bookkeeping cover K requests instead of K of each.

    python -m benchmarks.bench_staging [--smoke] [--out CSV]

``--smoke`` gates (exit 1) on K=4 beating K=1 staged-tokens/s — the CI
check that batching stays a speedup, not just a code path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Row, emit_rows

KS = (1, 4)


def _setup(slots_per_class: int = 8):
    from repro.configs import get_config
    from repro.core.bricks import decompose
    from repro.core.plan import compile_plan
    from repro.core.tabm import SlotClassPool
    from repro.launch.steps import init_params
    from repro.telemetry.probes import WallProbe

    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    pool = SlotClassPool.from_config(cfg, slots_per_class=slots_per_class)
    plan = compile_plan(decompose(cfg), params, tabm=pool,
                        probe=WallProbe())
    cls = pool.classify(cfg.vision_tokens, 1)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal(
        (1, cfg.vision_tokens, cfg.vision_feat_dim)).astype(np.float32) * .02
    return cfg, plan, pool, cls, feats


def _stage_loop(plan, pool, cls, feats, k: int, iters: int) -> float:
    """Stage ``iters`` microbatches of K requests, draining after each so
    the ring never stalls; returns staged vision tokens per second."""
    ring = pool.ring(cls)
    batch = [{"vision_feats": feats} for _ in range(k)]

    def once():
        slots = plan.produce_many(batch, slot_class=cls)
        assert slots is not None and len(slots) == k
        for slot in slots:
            got = plan.consume(slot_class=cls)
            assert got is not None
            plan.release(got[0], slot_class=cls)

    once()                                     # warmup: compile both paths
    jax.block_until_ready(ring.pool)
    t0 = time.perf_counter()
    for _ in range(iters):
        once()
    jax.block_until_ready(ring.pool)
    dt = time.perf_counter() - t0
    return (k * iters * feats.shape[1]) / dt


def run_bench(iters: int):
    cfg, plan, pool, cls, feats = _setup()
    rates = {k: _stage_loop(plan, pool, cls, feats, k, iters) for k in KS}
    rows = [
        Row(f"staging/produce_many/K={k}", 0.0,
            f"staged_tokens_per_s={rates[k]:.0f} class={cls} "
            f"iters={iters}")
        for k in KS
    ]
    ratio = rates[KS[-1]] / max(rates[KS[0]], 1e-9)
    rows.append(Row("staging/produce_many/speedup", 0.0,
                    f"K{KS[-1]}_over_K{KS[0]}={ratio:.2f}x (one batched "
                    f"projector call + one strided slab commit per "
                    f"microbatch)"))
    # measured per-brick staging ledger from the plan's wall-time probe
    ledger = plan.probe.to_ledger(meta={"bench": "staging"})
    return rows, rates, ratio, ledger


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="K=1 vs K=4 TABM staging throughput")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer iterations, gate on K=4 > K=1")
    ap.add_argument("--iters", type=int, default=None,
                    help="staging microbatches per K (default 64; 24 "
                         "under --smoke)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this path (CI "
                         "artifact)")
    ap.add_argument("--bench-json", default=None,
                    help="fold rows/metrics/measured ledger into this "
                         "versioned BENCH_<pr>.json (shared telemetry "
                         "writer)")
    args = ap.parse_args(argv)
    iters = args.iters or (24 if args.smoke else 64)
    rows, rates, ratio, ledger = run_bench(iters)
    from repro.telemetry.writer import metric
    emit_rows(
        rows, out=args.out, bench_json=args.bench_json, section="staging",
        metrics={
            # raw wall-clock throughputs are machine-dependent: recorded
            # for the trajectory, not CI-gated (the K4>K1 smoke below and
            # the deterministic fleet metrics carry the gates)
            f"staged_tokens_per_s_k{k}": metric(rates[k], gate=False)
            for k in KS} | {
            "staging_speedup_k4_over_k1": metric(ratio, gate=False)},
        ledger=ledger)
    if args.smoke and ratio <= 1.0:            # gate, not just a report
        print(f"FAIL: batched staging is not faster (K=4/K=1 = "
              f"{ratio:.2f}x)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
